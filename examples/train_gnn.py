"""End-to-end GNN training on a synthetic Reddit-shaped graph — the
paper's own workload, with AutoSAGE-scheduled aggregation.

Full-graph training (default):

    PYTHONPATH=src python examples/train_gnn.py [--epochs 30]

Minibatch training through the batch scheduler — every step samples an
induced subgraph, and `BatchScheduler` shares bucketed schedule
decisions and one probe budget across the whole stream instead of
probing per subgraph:

    PYTHONPATH=src python examples/train_gnn.py --minibatch 1024 \
        --epochs 5 --probe-budget-ms 2000

Fleet mode — N subprocess trainers share ONE schedule cache
(merge-on-flush under a lockfile; each trainer opens buckets warm from
its peers' probes and re-probes buckets whose observed runtime drifts):

    PYTHONPATH=src python examples/train_gnn.py --minibatch 1024 \
        --epochs 2 --workers 4 --cache fleet_cache.json
"""
import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import AutoSage, BatchScheduler, ScheduleCache
from repro.models.gnn import init_gnn, sage_forward, sage_minibatch_forward
from repro.sparse import reddit_like
from repro.sparse.csr import TRANSPOSE_STATS


def make_data(graph, classes, in_dim, seed=0):
    n = graph.n_rows
    rng = np.random.default_rng(seed)
    # synthetic node features + labels with graph-correlated signal
    feats = rng.standard_normal((n, in_dim)).astype(np.float32)
    labels = feats[:, 0] * 3 + rng.standard_normal(n) * 0.3
    labels = np.digitize(
        labels, np.quantile(labels, np.linspace(0, 1, classes + 1)[1:-1])
    ).astype(np.int32)
    return jnp.asarray(feats), jnp.asarray(labels)


def train_full(args, cfg, graph, x, y, classes, in_dim):
    sage = AutoSage(cache=ScheduleCache(path=None))
    params = init_gnn(cfg, jax.random.PRNGKey(0), in_dim, classes)

    def loss_fn(p):
        # fully scheduled step: forward SpMMs AND their backward
        # (op="spmm_bwd_b" on the memoized transpose) each get their own
        # decision. All decides + probes run host-side at trace time, so
        # the jitted step re-probes nothing.
        logits = sage_forward(p, graph, x, sage=sage)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], 1).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    lr = 0.05
    t0 = time.time()
    for epoch in range(args.epochs):
        loss, g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        if epoch % 5 == 0 or epoch == args.epochs - 1:
            print(f"epoch {epoch:3d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    # show what the scheduler picked, fwd and bwd
    d = sage.decide(graph, cfg.d_model, "spmm")
    print(f"scheduler choice for aggregation at F={cfg.d_model}: {d.choice}")
    n_bwd = len(sage.cache.keys_for_op("spmm_bwd_b"))
    print(
        f"backward decisions cached (op=spmm_bwd_b): {n_bwd}; "
        f"csr transposes built={TRANSPOSE_STATS['built']} "
        f"reused={TRANSPOSE_STATS['hits']}"
    )


def train_minibatch(args, cfg, graph, x, y, classes, in_dim):
    """Sampled-subgraph training: one BatchScheduler serves the whole
    stream of per-step induced subgraphs (one probe per schedule bucket,
    provisional baseline until the budget reaches a bucket). Each
    step's wall time feeds `observe` — a coarse signal (fwd+bwd, not
    the aggregation kernel alone, so kernel-level drift is diluted by
    the step's fixed cost); a production trainer would time the
    scheduled aggregation call itself, as tests/test_drift.py and the
    shared_smoke drift phase do."""
    sage = AutoSage(
        cache=ScheduleCache(path=args.cache or None, shared=args.shared or None),
        probe_iters=2, probe_cap_ms=200, probe_frac=0.25,
    )
    params = init_gnn(cfg, jax.random.PRNGKey(0), in_dim, classes)
    rng = np.random.default_rng(1 + args.worker_id)
    lr, t0 = 0.05, time.time()
    steps_per_epoch = max(1, graph.n_rows // args.minibatch)

    with BatchScheduler(sage, probe_budget_ms=args.probe_budget_ms) as bs:
        for epoch in range(args.epochs):
            losses = []
            for _ in range(steps_per_epoch):
                rows = np.sort(
                    rng.choice(graph.n_rows, size=args.minibatch, replace=False)
                )
                sub = graph.row_slice(rows)
                yb = y[jnp.asarray(rows)]

                def loss_fn(p):
                    logits = sage_minibatch_forward(p, sub, rows, x, sage=bs)
                    logp = jax.nn.log_softmax(logits)
                    return -jnp.take_along_axis(logp, yb[:, None], 1).mean()

                t_step = time.perf_counter()
                loss, g = jax.value_and_grad(loss_fn)(params)
                jax.block_until_ready(loss)
                step_ms = (time.perf_counter() - t_step) * 1e3
                # the step's decides (forward spmm + its scheduled
                # backward) already bucketed this subgraph; last_bucket
                # avoids a second feature extraction per step
                bs.observe(bs.last_bucket, step_ms)
                params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
                losses.append(float(loss))
            print(
                f"epoch {epoch:3d} loss {np.mean(losses):.4f} "
                f"({time.time()-t0:.1f}s)  stream={bs.stats()}"
            )
    s = bs.stats()
    print(
        f"batched decide: {s['decides']} decides -> {s['buckets']} buckets, "
        f"{s['probes_run']} probes ({s['probes_avoided']} avoided, "
        f"{s['warm_cache_opens']} opened warm from the shared cache), "
        f"drift: {s['drift_flags']} flags / {s['drift_reprobes']} re-probes / "
        f"{s['drift_flips']} flips, probe budget spent "
        f"{s['probe_spent_ms']:.0f}/{s['probe_budget_ms']:.0f}ms"
    )
    for row in bs.bucket_stats():
        print(f"  bucket {row['bucket']}: hits={row['hits']} choice={row['choice']}")
    print(
        f"transposed layouts: built={TRANSPOSE_STATS['built']} "
        f"reused={TRANSPOSE_STATS['hits']} "
        "(backward SpMMs share the per-structure transpose cache)"
    )
    if args.stats_json:
        with open(args.stats_json, "w") as fh:
            json.dump(s, fh)


def train_fleet(args):
    """Spawn --workers subprocess trainers against ONE shared schedule
    cache: each worker re-runs this script in --minibatch mode with
    AUTOSAGE_CACHE_SHARED=1, so bucket probes paid by one worker are
    opened warm by the rest (merge-on-flush, core/cache.py)."""
    cache = args.cache or "fleet_cache.json"
    procs, stats_paths = [], []
    for w in range(args.workers):
        stats_path = f"{cache}.worker{w}.stats.json"
        stats_paths.append(stats_path)
        cmd = [
            sys.executable, os.path.abspath(__file__),
            "--minibatch", str(args.minibatch), "--epochs", str(args.epochs),
            "--scale", str(args.scale), "--cache", cache, "--shared",
            "--probe-budget-ms", str(args.probe_budget_ms),
            "--worker-id", str(w), "--stats-json", stats_path,
        ]
        env = {**os.environ, "AUTOSAGE_CACHE_SHARED": "1"}
        # a worker that inherits no backend must not probe accelerator
        # metadata (minutes of hang on cloud hosts); parent's choice wins
        env.setdefault("JAX_PLATFORMS", "cpu")
        procs.append(subprocess.Popen(cmd, env=env))
    rcs = [p.wait() for p in procs]
    if any(rcs):
        raise SystemExit(f"worker exit codes: {rcs}")
    totals = {"decides": 0, "probes_run": 0, "warm_cache_opens": 0,
              "drift_reprobes": 0, "drift_flips": 0}
    for sp in stats_paths:
        with open(sp) as fh:
            s = json.load(fh)
        for k in totals:
            totals[k] += s.get(k, 0)
        os.unlink(sp)
    print(
        f"fleet of {args.workers}: {totals['decides']} decides, "
        f"{totals['probes_run']} probes total, "
        f"{totals['warm_cache_opens']} buckets opened warm from peers, "
        f"{totals['drift_reprobes']} drift re-probes "
        f"({totals['drift_flips']} flipped); merged cache: {cache}"
    )


def finish_obs(args):
    """--obs epilogue: flush the flight-recorder artifacts and print the
    end-of-run metrics summary + estimate-accuracy scorecard (mean abs
    estimate error per op family). In fleet mode each worker flushes its
    own metrics_<pid> snapshot; aggregate them afterwards with
    `python -m repro.obs_cli summary`."""
    if not args.obs:
        return
    from repro.core import obs

    paths = obs.flush(force=True)
    print()
    print(obs.summary_text())
    if paths.get("trace"):
        print(
            f"[obs] artifacts in {os.path.dirname(paths['trace'])} "
            "(trace_*.json opens in ui.perfetto.dev; "
            "python -m repro.obs_cli summary/explain reads the rest)"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--minibatch", type=int, default=0,
                    help="rows per sampled subgraph; 0 = full-graph training")
    ap.add_argument("--probe-budget-ms", type=float, default=2000.0,
                    help="shared probe budget for the minibatch stream")
    ap.add_argument("--cache", default="",
                    help="schedule cache path (minibatch mode); empty = in-memory")
    ap.add_argument("--workers", type=int, default=0,
                    help="fleet mode: N subprocess trainers against one "
                         "shared cache (implies --minibatch)")
    ap.add_argument("--shared", action="store_true",
                    help="merge-on-flush shared cache "
                         "(set automatically in fleet workers)")
    ap.add_argument("--obs", action="store_true",
                    help="flight recorder: sets AUTOSAGE_OBS=1 (spans + "
                         "metrics + scorecard) and prints the end-of-run "
                         "summary; artifacts land in AUTOSAGE_OBS_DIR "
                         "(default results/obs)")
    ap.add_argument("--worker-id", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--stats-json", default="", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.obs:
        # before any decide: fleet workers inherit it through the env
        os.environ["AUTOSAGE_OBS"] = "1"

    if args.workers:
        if not args.minibatch:
            args.minibatch = 1024
        train_fleet(args)
        finish_obs(args)
        return

    cfg = get_config("gnn_sage")
    graph = reddit_like(scale=args.scale)
    classes, in_dim = 16, 64
    x, y = make_data(graph, classes, in_dim)

    if args.minibatch:
        train_minibatch(args, cfg, graph, x, y, classes, in_dim)
    else:
        train_full(args, cfg, graph, x, y, classes, in_dim)
    finish_obs(args)


if __name__ == "__main__":
    main()
