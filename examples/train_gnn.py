"""End-to-end GNN training on a synthetic Reddit-shaped graph — the
paper's own workload, with AutoSAGE-scheduled aggregation.

Full-graph training (default):

    PYTHONPATH=src python examples/train_gnn.py [--epochs 30]

Minibatch training through the batch scheduler — every step samples an
induced subgraph, and `BatchScheduler` shares bucketed schedule
decisions and one probe budget across the whole stream instead of
probing per subgraph:

    PYTHONPATH=src python examples/train_gnn.py --minibatch 1024 \
        --epochs 5 --probe-budget-ms 2000
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import AutoSage, BatchScheduler, ScheduleCache
from repro.models.gnn import init_gnn, sage_forward, sage_minibatch_forward
from repro.sparse import reddit_like


def make_data(graph, classes, in_dim, seed=0):
    n = graph.n_rows
    rng = np.random.default_rng(seed)
    # synthetic node features + labels with graph-correlated signal
    feats = rng.standard_normal((n, in_dim)).astype(np.float32)
    labels = feats[:, 0] * 3 + rng.standard_normal(n) * 0.3
    labels = np.digitize(
        labels, np.quantile(labels, np.linspace(0, 1, classes + 1)[1:-1])
    ).astype(np.int32)
    return jnp.asarray(feats), jnp.asarray(labels)


def train_full(args, cfg, graph, x, y, classes, in_dim):
    sage = AutoSage(cache=ScheduleCache(path=None))
    params = init_gnn(cfg, jax.random.PRNGKey(0), in_dim, classes)

    def loss_fn(p):
        logits = sage_forward(p, graph, x)  # AutoSAGE inside would re-probe
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], 1).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    lr = 0.05
    t0 = time.time()
    for epoch in range(args.epochs):
        loss, g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        if epoch % 5 == 0 or epoch == args.epochs - 1:
            print(f"epoch {epoch:3d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    # show what the scheduler picks for this graph at this width
    d = sage.decide(graph, cfg.d_model, "spmm")
    print(f"scheduler choice for aggregation at F={cfg.d_model}: {d.choice}")


def train_minibatch(args, cfg, graph, x, y, classes, in_dim):
    """Sampled-subgraph training: one BatchScheduler serves the whole
    stream of per-step induced subgraphs (one probe per schedule bucket,
    provisional baseline until the budget reaches a bucket)."""
    sage = AutoSage(
        cache=ScheduleCache(path=args.cache or None),
        probe_iters=2, probe_cap_ms=200, probe_frac=0.25,
    )
    params = init_gnn(cfg, jax.random.PRNGKey(0), in_dim, classes)
    rng = np.random.default_rng(1)
    lr, t0 = 0.05, time.time()
    steps_per_epoch = max(1, graph.n_rows // args.minibatch)

    with BatchScheduler(sage, probe_budget_ms=args.probe_budget_ms) as bs:
        for epoch in range(args.epochs):
            losses = []
            for _ in range(steps_per_epoch):
                rows = np.sort(
                    rng.choice(graph.n_rows, size=args.minibatch, replace=False)
                )
                sub = graph.row_slice(rows)
                yb = y[jnp.asarray(rows)]

                def loss_fn(p):
                    logits = sage_minibatch_forward(p, sub, rows, x, sage=bs)
                    logp = jax.nn.log_softmax(logits)
                    return -jnp.take_along_axis(logp, yb[:, None], 1).mean()

                loss, g = jax.value_and_grad(loss_fn)(params)
                params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
                losses.append(float(loss))
            print(
                f"epoch {epoch:3d} loss {np.mean(losses):.4f} "
                f"({time.time()-t0:.1f}s)  stream={bs.stats()}"
            )
    s = bs.stats()
    print(
        f"batched decide: {s['decides']} decides -> {s['buckets']} buckets, "
        f"{s['probes_run']} probes ({s['probes_avoided']} avoided), "
        f"probe budget spent {s['probe_spent_ms']:.0f}/"
        f"{s['probe_budget_ms']:.0f}ms"
    )
    for row in bs.bucket_stats():
        print(f"  bucket {row['bucket']}: hits={row['hits']} choice={row['choice']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--minibatch", type=int, default=0,
                    help="rows per sampled subgraph; 0 = full-graph training")
    ap.add_argument("--probe-budget-ms", type=float, default=2000.0,
                    help="shared probe budget for the minibatch stream")
    ap.add_argument("--cache", default="",
                    help="schedule cache path (minibatch mode); empty = in-memory")
    args = ap.parse_args()

    cfg = get_config("gnn_sage")
    graph = reddit_like(scale=args.scale)
    classes, in_dim = 16, 64
    x, y = make_data(graph, classes, in_dim)

    if args.minibatch:
        train_minibatch(args, cfg, graph, x, y, classes, in_dim)
    else:
        train_full(args, cfg, graph, x, y, classes, in_dim)


if __name__ == "__main__":
    main()
