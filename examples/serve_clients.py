"""Online serving demo: concurrent clients against one `GNNServer`.

Each client thread streams sampled subgraphs (minibatch-style traffic)
into a shared serving process. Every request is answered within the
per-request decision budget (`AUTOSAGE_SERVE_BUDGET_MS`, default 50 ms):
warm-cache and transfer-tier decisions inline, cold buckets served the
guardrail-safe provisional baseline while a background probe-worker
thread upgrades them in place — a probe never blocks a request.

    PYTHONPATH=src python examples/serve_clients.py
    PYTHONPATH=src python examples/serve_clients.py --clients 8 \
        --requests 128 --budget-ms 25

Warm-start from a fleet-shared cache (probes other processes paid for):

    PYTHONPATH=src python examples/serve_clients.py \
        --cache fleet_cache.json

Then replay the served decision stream deterministically (no probes,
unseen buckets raise):

    PYTHONPATH=src python examples/serve_clients.py \
        --cache fleet_cache.json --replay

Per-bucket p50/p99 latency tables come from `repro.core.obs`
(AUTOSAGE_OBS=1 additionally drops Prometheus/Perfetto artifacts); see
docs/ARCHITECTURE.md for the tier semantics.
"""
import argparse
import sys
import threading
import time

sys.path.insert(0, "src")

from repro.core import AutoSage, BatchScheduler, ScheduleCache, obs
from repro.launch.serve import GNNServer
from repro.sparse import fixed_degree, hub_skew, sample_subgraph_stream


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64,
                    help="subgraphs per pass, split across clients")
    ap.add_argument("--passes", type=int, default=2,
                    help="pass 1 cold-admits buckets; pass 2 serves warm")
    ap.add_argument("--f", type=int, default=16)
    ap.add_argument("--rows", type=int, default=256)
    ap.add_argument("--budget-ms", type=float, default=None)
    ap.add_argument("--cache", default=None)
    ap.add_argument("--replay", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # four degree regimes + one heavy-tailed: five schedule buckets
    parents = [fixed_degree(2048, d, seed=args.seed + i)
               for i, d in enumerate((3, 6, 12, 24))]
    parents.append(hub_skew(2048, 6, 0.10, 60, seed=args.seed + 4))
    stream = sample_subgraph_stream(
        parents, args.requests, rows_per_graph=args.rows, seed=args.seed + 5
    )

    sage = AutoSage(
        cache=ScheduleCache(path=args.cache, replay_only=args.replay),
        probe_iters=1, probe_cap_ms=50, probe_frac=0.25,
    )
    server = GNNServer(
        BatchScheduler(sage, probe_budget_ms=10_000),
        budget_ms=args.budget_ms,
    )

    def client(cid: int) -> None:
        for g in stream[cid::args.clients]:
            r = server.submit(g, args.f, "spmm")
            if r.latency_ms > server.budget_ms:
                print(f"[client {cid}] OVER BUDGET: {r.latency_ms:.2f}ms "
                      f"tier={r.tier} bucket={r.bucket}")
            time.sleep(0.001)  # client think time

    for p in range(args.passes):
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        print(f"[pass {p + 1}] {len(stream)} requests / "
              f"{args.clients} clients in {wall * 1e3:.0f}ms")
        server.drain(timeout_s=60.0)  # let background probes finish

    stats = server.close(finalize=not args.replay)
    print(f"\nserved {stats['requests']} requests over {stats['buckets']} "
          f"buckets  budget={stats['budget_ms']:.0f}ms")
    for tier, n in sorted(stats["by_tier"].items()):
        print(f"  {tier:12s} {n}")
    print(f"  p50={stats['p50_ms']:.3f}ms  p99={stats['p99_ms']:.3f}ms  "
          f"max={stats['max_ms']:.3f}ms")
    print(f"  stalls={stats['stalls']}  over_budget={stats['over_budget']}  "
          f"background_upgrades={stats['upgrades']}")
    print("\nper-bucket latency (heaviest first):")
    for row in obs.serve_latency_table():
        tiers = ",".join(f"{t}:{n}" for t, n in row["tiers"].items())
        print(f"  {row['bucket'][:48]:48s} n={row['requests']:<4d} "
              f"p50={row['p50_ms']:.3f}ms p99={row['p99_ms']:.3f}ms [{tiers}]")
    return 0 if stats["stalls"] == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
