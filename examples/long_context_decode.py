"""Long-context decode via the paper's CSR attention (window + sinks).

    PYTHONPATH=src python examples/long_context_decode.py

Demonstrates the long_500k serving path at small scale: a reduced dense
LM decodes against a KV cache using the banded CSR pattern
(sliding_window_csr) instead of full attention — O(window) per token.
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.models import api


def main():
    cfg = reduced(get_config("qwen3_14b"))  # long_window=64, long_sinks=8
    params = api.init_model(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, prompt, gen = 2, 48, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt), 0, cfg.vocab)
    cache = api.init_cache(cfg, B, prompt + gen, jnp.float32)
    logits, cache = api.prefill(params, {"tokens": toks}, cfg, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]

    decode = jax.jit(
        lambda p, t, c: api.decode_step(p, t, cfg, c, long_ctx=True),
        donate_argnums=(2,),
    )
    t0 = time.time()
    outs = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    print(f"decoded {gen} tokens through CSR window+sink attention "
          f"({(time.time()-t0)/gen*1e3:.1f} ms/tok, window={cfg.long_window}, "
          f"sinks={cfg.long_sinks})")
    print("generated:", jnp.concatenate(outs, 1)[0, :12].tolist())


if __name__ == "__main__":
    main()
